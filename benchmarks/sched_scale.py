"""Scheduler scale benchmark: planning cost from 10k to 100k tracked twins.

The serving loop's SCHEDULE stage must not become the host bottleneck the
guard rotation already removed — the planner's contract is per-tick host
cost O(budget + log n), with the O(n) scoring fused on device
(twin/packed.py).  This benchmark isolates the planner from the rest of the
loop (no rings, no refits) and drives BOTH planners over the same synthetic
fleet dynamics:

  * `bucketed` — `PackedRefitScheduler`, the serving default: one fused
    device scoring call + PriorityBuckets winner pops;
  * `reference` — `RefitScheduler`, the O(n log n) dict-sorting oracle
    (fewer ticks; its per-plan cost is the point being retired).

Fleet dynamics per tick: every twin ingests a fixed telemetry chunk
(staleness drifts fleet-wide — the property that makes incremental host
structures useless and the fused device pass necessary), a random subset's
divergence jitters (guard folds), residents accrue residency and "deploy"
after a few ticks (watermark reset), and each planner's own plans are
applied — so slot turnover, eviction pressure, and voluntary release all
stay live across the sweep.

The acceptance gate, printed at the end: bucketed plan p50 grows <= 2x from
10k -> 100k twins.  `pressure_ms` times the federation's rebalance signal
(`pressure()`), which must also stay flat for the bucketed planner (fused
device reduction) and is O(n) host work for the reference.

Emitted to bench_out/sched_scale.csv by benchmarks/run.py
(`--only sched_scale`); `--smoke` runs tiny fleets for CI.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_rows, write_csv
from repro.twin.packed import PackedFleet
from repro.twin.scheduler import (PackedRefitScheduler, RefitScheduler,
                                  SchedulerConfig, TwinRecord)

SLOTS = 64
MIN_SAMPLES = 32
CHUNK = 8             # samples ingested per twin per tick
DEPLOY_AFTER = 3      # resident ticks before the synthetic "deploy"
JITTER = 256          # twins whose divergence moves per tick
WARMUP = 2            # ticks excluded from stats (jit compile lands here)


def _make_fleet(n_twins: int, seed: int) -> PackedFleet:
    """A mid-mission fleet: everyone registered and sampled past readiness,
    most deployed, divergence long-tailed so eviction pressure exists."""
    rng = np.random.default_rng(seed)
    fleet = PackedFleet(n_twins)
    fleet.twin_id[:] = np.arange(n_twins)
    fleet.registered[:] = True
    fleet.samples[:] = MIN_SAMPLES + rng.integers(0, 4 * MIN_SAMPLES,
                                                  n_twins)
    fleet.deployed[:] = rng.random(n_twins) < 0.7
    fleet.samples_at_deploy[:] = np.where(
        fleet.deployed, (fleet.samples * rng.random(n_twins)).astype(
            np.int64), 0)
    fleet.set_divergence(slice(None), rng.exponential(0.05, n_twins))
    return fleet


def _advance(fleet: PackedFleet, rng) -> None:
    """One tick of fleet dynamics, vectorized (untimed — only plan cost is
    under measurement)."""
    fleet.samples += CHUNK
    jitter = rng.integers(0, fleet.capacity, min(JITTER, fleet.capacity))
    fleet.set_divergence(jitter, np.abs(
        fleet.divergence[jitter] + rng.normal(0.0, 0.05, jitter.size)))
    res = np.nonzero(fleet.resident)[0]
    fleet.residency[res] += 1
    done = res[fleet.residency[res] >= DEPLOY_AFTER]
    fleet.samples_at_deploy[done] = fleet.samples[done]
    fleet.deployed[done] = True
    fleet.set_divergence(done, fleet.divergence[done] * 0.25)


def _apply(fleet: PackedFleet, slot_rows: np.ndarray, row_slot: dict,
           plan) -> None:
    """Apply a plan to the packed state (twin_id == row in this driver)."""
    for tid in plan.evict + plan.release:
        slot_rows[row_slot.pop(tid)] = fleet.capacity
        fleet.resident[tid] = False
        fleet.residency[tid] = 0
    for slot, tid in plan.admit:
        slot_rows[slot] = tid
        row_slot[tid] = slot
        fleet.resident[tid] = True
        fleet.residency[tid] = 0


def _fleet_to_records(fleet: PackedFleet,
                      row_slot: dict) -> dict[int, TwinRecord]:
    """Rebuild the reference planner's dict view (untimed — the O(n) dict
    materialization is the data layout the packed refactor retired, not the
    planning cost under measurement)."""
    return {row: TwinRecord(
        twin_id=row, ring_slot=row, refit_slot=row_slot.get(row),
        samples=int(fleet.samples[row]),
        samples_at_deploy=int(fleet.samples_at_deploy[row]),
        deployed=bool(fleet.deployed[row]),
        residency=int(fleet.residency[row]),
        divergence=float(fleet.divergence[row]))
        for row in range(fleet.capacity)}


def _drive(n_twins: int, planner: str, ticks: int, seed: int = 0) -> dict:
    cfg = SchedulerConfig(slots=SLOTS, min_samples=MIN_SAMPLES,
                          min_residency=2, max_residency=8)
    rng = np.random.default_rng(seed)
    fleet = _make_fleet(n_twins, seed)
    slot_rows = np.full((SLOTS,), fleet.capacity, np.int64)
    row_slot: dict[int, int] = {}
    sched = (PackedRefitScheduler(cfg) if planner == "bucketed"
             else RefitScheduler(cfg))

    plan_s: list[float] = []
    turnover = 0
    for t in range(WARMUP + ticks):
        if planner == "bucketed":
            t0 = time.perf_counter()
            plan = sched.plan(fleet, slot_rows)
            dt = time.perf_counter() - t0
        else:
            twins = _fleet_to_records(fleet, row_slot)
            t0 = time.perf_counter()
            plan = sched.plan(twins)
            dt = time.perf_counter() - t0
        if t >= WARMUP:
            plan_s.append(dt)
            turnover += len(plan.admit) + len(plan.release)
        _apply(fleet, slot_rows, row_slot, plan)
        _advance(fleet, rng)

    if planner == "bucketed":
        sched.pressure(fleet)            # warm the fused-reduction compile
        t0 = time.perf_counter()
        pressure = sched.pressure(fleet)
    else:
        twins = _fleet_to_records(fleet, row_slot)
        t0 = time.perf_counter()
        pressure = sched.pressure(twins)
    pressure_ms = (time.perf_counter() - t0) * 1e3

    q = np.quantile(np.asarray(plan_s), [0.5, 0.99]) * 1e3
    return {
        "twins": n_twins, "planner": planner, "slots": SLOTS,
        "ticks": ticks,
        "plan_p50_ms": round(float(q[0]), 3),
        "plan_p99_ms": round(float(q[1]), 3),
        "pressure_ms": round(pressure_ms, 3),
        "turnover": turnover,                 # sanity: slots actually churn
        "pressure": round(pressure, 1),
    }


def _check_flat(rows: list[dict]) -> None:
    """The acceptance gate: bucketed plan p50 within 2x across the sweep."""
    group = sorted((r for r in rows if r["planner"] == "bucketed"),
                   key=lambda r: r["twins"])
    if len(group) < 2:
        return
    lo, hi = group[0], group[-1]
    ratio = hi["plan_p50_ms"] / max(lo["plan_p50_ms"], 1e-9)
    flat = ("FLAT (O(budget + log n) holds)" if ratio <= 2.0
            else "NOT FLAT — planner scaling regression")
    print(f"[sched_scale] bucketed plan p50 {lo['twins']} -> {hi['twins']} "
          f"twins: {lo['plan_p50_ms']:.3f} -> {hi['plan_p50_ms']:.3f} "
          f"ms ({ratio:.2f}x) — {flat}")
    ref = {r["twins"]: r for r in rows if r["planner"] == "reference"}
    for r in group:
        other = ref.get(r["twins"])
        if other:
            speedup = other["plan_p50_ms"] / max(r["plan_p50_ms"], 1e-9)
            print(f"[sched_scale] {r['twins']} twins: bucketed "
                  f"{r['plan_p50_ms']:.3f} ms vs reference "
                  f"{other['plan_p50_ms']:.3f} ms ({speedup:.1f}x faster)")


def run(quick: bool = True, smoke: bool = False) -> None:
    if smoke:
        sizes, ticks, ref_ticks = [2000, 8000], 8, 4
    elif quick:
        sizes, ticks, ref_ticks = [10_000, 30_000, 100_000], 20, 4
    else:
        sizes, ticks, ref_ticks = [10_000, 30_000, 100_000, 300_000], 40, 6
    rows = [_drive(n, "bucketed", ticks) for n in sizes]
    rows += [_drive(n, "reference", ref_ticks) for n in sizes]
    print_rows("schedule planning at scale: fused device scoring vs "
               "dict sorting", rows)
    _check_flat(rows)
    path = write_csv("sched_scale.csv", rows)
    print(f"[sched_scale] wrote {path}")


if __name__ == "__main__":
    run()
