"""Shared benchmark utilities: wall-clock timing + CSV emission."""
from __future__ import annotations

import csv
import time
from pathlib import Path

import jax

OUT_DIR = Path(__file__).resolve().parent.parent / "bench_out"


def time_fn(fn, *args, warmup: int = 1, repeats: int = 5) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def write_csv(name: str, rows: list[dict]) -> Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / name
    if rows:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    return path


def print_rows(title: str, rows: list[dict]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        return
    keys = list(rows[0].keys())
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r[k]) for k in keys))
