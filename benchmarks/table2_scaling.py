"""Paper Fig. 4 + Table II: F8 Crusader model-recovery cost vs model
dimension, unoptimized vs optimized, with hardware-resource analogues.

Dimension scaling follows the deployment story (one twin per airframe; see
systems/f8_crusader.py): dimension d = 3 * n_aircraft.  Per dimension we
time ONE fused MR training step (fwd+bwd of the full MERINDA pipeline) in
two implementations:

  * naive     — per-timestep GRU with separate z/r/c matmuls and no input
                hoisting (the paper's unoptimized FPGA loop), naive
                per-step RK4 library evaluation.
  * optimized — fused-gate, input-hoisted GRU scan + fused RK4 (the
                kernels/ formulation the Pallas kernels implement).

CPU wall-clock gives the measured speedup (relative, 1 core); the
TPU-modeled latency columns derive from the roofline model at the same
shapes (197 TFLOP/s, 819 GB/s), and the resource columns are the FPGA
analogues: params bytes ~ LUT/FF, MXU matmul FLOPs ~ DSP work, kernel VMEM
working set ~ BRAM (DESIGN.md §2 mapping table).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_rows, time_fn, write_csv
from repro.core.merinda import Merinda, MerindaConfig
from repro.data.pipeline import WindowDataset
from repro.systems.f8_crusader import F8Crusader
from repro.systems.simulate import simulate_batch
from repro.train.optimizer import adamw, apply_updates

PEAK = 197e12
HBM = 819e9


def _gru_scan_naive(xs, h0, wx, wh, b):
    """Unoptimized GRU: 6 small matmuls PER TIMESTEP, nothing hoisted —
    the software analogue of the paper's no-pragma FPGA baseline."""
    H = h0.shape[-1]
    wxz, wxr, wxc = wx[:, :H], wx[:, H:2 * H], wx[:, 2 * H:]
    whz, whr, whc = wh[:, :H], wh[:, H:2 * H], wh[:, 2 * H:]
    bz, br, bc = b[:H], b[H:2 * H], b[2 * H:]

    def step(h, x_t):
        z = jax.nn.sigmoid(x_t @ wxz + h @ whz + bz)
        r = jax.nn.sigmoid(x_t @ wxr + h @ whr + br)
        c = jnp.tanh(x_t @ wxc + (r * h) @ whc + bc)
        h = (1.0 - z) * h + z * c
        return h, h

    hT, hs = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1), hT


def _make_step(model, optimized: bool):
    opt = adamw(lr=1e-3)

    def loss_fn(params, batch):
        if optimized:
            return model.loss(params, batch)
        # monkeypatch-free naive path: recompute encode with the naive scan
        y_win, u_win = batch
        xs = jnp.concatenate([y_win[:, :-1, :], u_win], axis=-1)
        norm = jax.lax.stop_gradient(params["norm"])
        xs = (xs - norm["mu"]) / norm["sigma"]
        B = xs.shape[0]
        g = params["gru"]
        hs, hT = _gru_scan_naive(xs, jnp.zeros((B, model.cfg.hidden)),
                                 g["wx"], g["wh"], g["b"])
        summary = jnp.concatenate([hT, hs.mean(axis=1)], axis=-1)
        hd = params["head"]
        h = jax.nn.relu(summary @ hd["w1"] + hd["b1"])
        raw = (h @ hd["w2"] + hd["b2"]) * model.cfg.theta_scale
        L = model.lib.size
        theta = (raw[..., :model.cfg.n * L].reshape(B, model.cfg.n, L)
                 / norm["phi_scale"][None, None, :])
        y_est = model.decode(theta, y_win[:, 0, :], u_win)
        return jnp.mean(jnp.square(y_est - y_win)), {}

    @jax.jit
    def step(params, opt_state, batch):
        (l, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                  batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, l

    return opt, step


def _flops_bytes(model, batch_size, window):
    """Matmul FLOPs + weight/activation bytes of one MR fwd pass."""
    cfg = model.cfg
    d_in, H, L = cfg.n + cfg.m, cfg.hidden, model.lib.size
    T, B = window, batch_size
    gru = 2 * B * T * (d_in * 3 * H + H * 3 * H)
    head = 2 * B * (2 * H * cfg.head_hidden + cfg.head_hidden * cfg.n * L)
    rk4 = 2 * B * T * 4 * (L * cfg.n + L * cfg.order)   # contraction + lib
    flops = 3 * (gru + head + rk4)                       # fwd+bwd ~ 3x fwd
    w_bytes = 4 * (d_in * 3 * H + H * 3 * H
                   + 2 * H * cfg.head_hidden + cfg.head_hidden * cfg.n * L)
    act_bytes = 4 * B * T * (d_in + 3 * H + cfg.n + L)
    return flops, w_bytes, act_bytes


def run(quick: bool = True) -> list[dict]:
    dims = [21, 30, 60, 90] if quick else [21, 30, 39, 51, 60, 90, 120, 150]
    rows = []
    for d in dims:
        k = d // 3
        system = F8Crusader(n_aircraft=1)
        key = jax.random.PRNGKey(0)
        trace = simulate_batch(system, key, batch=max(2, k // 2),
                               horizon=120, noise_std=0.005)
        ds = WindowDataset.from_trace(trace.ys_noisy, trace.us, trace.dt,
                                      window=16, stride=8)
        # fleet of k twins == dimension 3k: batch k windows per step/twin
        B = 8 * k
        idx = np.arange(B) % ds.n_windows
        batch = (ds.y_win[idx], ds.u_win[idx])
        model = Merinda(MerindaConfig(n=3, m=1, order=3, dt=system.spec.dt,
                                      hidden=64, n_active=24))
        params = model.init(key, model.norm_stats(*batch))

        times = {}
        for name, optimized in [("naive", False), ("optimized", True)]:
            opt, step = _make_step(model, optimized)
            ostate = opt.init(params)
            times[name] = time_fn(step, params, ostate, batch,
                                  warmup=1, repeats=3)
        flops, w_bytes, act_bytes = _flops_bytes(model, B, 16)
        tpu_us = max(flops / PEAK, (w_bytes + act_bytes) / HBM) * 1e6
        rows.append({
            "dim": d, "fleet": k,
            "naive_ms": round(times["naive"] * 1e3, 2),
            "optimized_ms": round(times["optimized"] * 1e3, 2),
            "speedup": round(times["naive"] / times["optimized"], 2),
            "mxu_flops": int(flops),                  # DSP analogue
            "param_bytes": int(w_bytes),              # LUT/FF analogue
            "act_bytes": int(act_bytes),              # BRAM analogue
            "tpu_modeled_us": round(tpu_us, 1),
        })
    write_csv("table2_scaling.csv", rows)
    print_rows("Fig.4/Table II — F8 dimension sweep (naive vs optimized)",
               rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
